"""Roofline analysis per (arch x shape x mesh) from dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

Constants: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM, ~50 GB/s/link.
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs flags remat/dispatch waste.

The DVFS planner (the paper's technique) consumes these terms directly:
``repro.core.workloads.roofline_workload`` turns a row of this table into
a WorkloadProfile whose optimal clock and energy saving are computed just
like the paper's per-FFT-length optimum.
"""
from __future__ import annotations

import dataclasses
import json

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.hardware import TPU_V5E, DeviceSpec


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                # per-device FLOPs of one step
    hbm_bytes: float                # per-device HBM traffic
    collective_bytes: float         # per-device collective traffic
    model_flops: float              # 6*N(active)*D tokens, global
    device: DeviceSpec = TPU_V5E

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / self.device.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.device.hbm_bandwidth

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.device.link_bandwidth

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time (perfect overlap = max of terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops) — remat/dispatch waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achieved fraction of the dominant roofline: how close the
        OTHER terms come to the bound (1.0 = perfectly balanced use of
        the bottleneck resource)."""
        if self.step_s == 0:
            return 0.0
        return (self.model_flops / self.chips / self.device.peak_flops
                ) / self.step_s

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_ms": round(self.compute_s * 1e3, 3),
            "memory_ms": round(self.memory_s * 1e3, 3),
            "collective_ms": round(self.collective_s * 1e3, 3),
            "bound": self.bound,
            "useful_ratio": round(self.useful_ratio, 3),
            "mfu_roofline": round(self.roofline_fraction, 3),
        }


def model_flops_for(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """6*N*D (6*N_active*D for MoE); D = tokens processed by the step."""
    n = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens               # forward only
    tokens = shape.global_batch                # one token per sequence
    return 2.0 * n * tokens


def analytic_memory_bytes(cfg: ArchConfig, shape: ShapeSpec, chips: int
                          ) -> dict[str, float]:
    """First-principles HBM traffic per device per step (bytes).

    The HLO-parsed byte count (recorded in the artifact) is a gross UPPER
    bound: the CPU backend fuses at much finer granularity than TPU and
    the parser cannot see in-place aliasing of donated cache/state
    buffers.  This breakdown is the standard napkin-roofline accounting
    instead; every component is listed so §Perf iterations can attack the
    dominant one.
    """
    n_params = cfg.param_count()
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill")
                                   else 1)
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    out: dict[str, float] = {}

    if shape.kind == "train":
        out["weights_io"] = 3 * n_params * 2          # read fwd+bwd, write
        out["optimizer_io"] = 24 * n_params           # grads + m/v, f32
        out["activations_io"] = 3 * L * tokens * d * 2
        out["logits_io"] = 4 * tokens * V * 4         # chunked CE fwd+bwd
    elif shape.kind == "prefill":
        out["weights_io"] = n_params * 2
        out["activations_io"] = 2 * L * tokens * d * 2
        out["logits_io"] = shape.global_batch * V * 4
    else:
        out["weights_io"] = n_params * 2
        out["activations_io"] = 2 * L * shape.global_batch * d * 2

    # attention-score traffic (jnp chunked flash materialises score chunks;
    # the Pallas-flash §Perf optimisation removes this term)
    s = shape.seq_len
    if cfg.family in ("ssm",):
        q = cfg.ssm.chunk
        h = cfg.ssm.expand * d // cfg.ssm.head_dim
        if shape.kind in ("train", "prefill"):
            # L matrices (B, S/Q, H, Q, Q) f32 -> B*S*H*Q elements/pass
            passes = 4 if shape.kind == "train" else 2
            out["ssd_chunk_io"] = passes * L * shape.global_batch * s * q * h * 4
    else:
        n_attn = L
        if cfg.family == "hybrid":
            n_attn = cfg.n_layers // max(cfg.shared_attn_every, 1)
        kv_len = s
        if cfg.sliding_window and cfg.local_per_global:
            # 5 of 6 layers see only the window
            frac_local = cfg.local_per_global / (cfg.local_per_global + 1)
            kv_len = (frac_local * cfg.sliding_window
                      + (1 - frac_local) * s)
        heads = cfg.n_heads
        if shape.kind == "train":
            out["attn_scores_io"] = (4 * n_attn * shape.global_batch
                                     * heads * s * kv_len / 2 * 4)
        elif shape.kind == "prefill":
            out["attn_scores_io"] = (2 * n_attn * shape.global_batch
                                     * heads * s * kv_len / 2 * 4)
        else:
            # decode: read the KV cache once per step
            if cfg.mla is not None:
                per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                out["kv_cache_io"] = L * shape.global_batch * s * per_tok * 2
            else:
                hd = cfg.resolved_head_dim
                out["kv_cache_io"] = (n_attn * shape.global_batch * s
                                      * 2 * cfg.n_kv_heads * hd * 2)
    if cfg.family == "hybrid" and shape.kind == "decode":
        hd = cfg.resolved_head_dim
        n_sites = cfg.n_layers // max(cfg.shared_attn_every, 1)
        out["kv_cache_io"] = (n_sites * shape.global_batch * s
                              * 2 * cfg.n_kv_heads * hd * 2)

    if cfg.moe is not None and shape.kind in ("train", "prefill"):
        passes = 4 if shape.kind == "train" else 2
        out["moe_dispatch_io"] = (passes * (L - cfg.n_dense_layers) * tokens
                                  * cfg.moe.top_k * 1.25 * d * 2)

    out["total"] = float(sum(out.values()))
    return {k: v / chips for k, v in out.items()}


def roofline_from_artifact(path: str) -> RooflineTerms:
    from repro.configs import get_arch, get_shape
    with open(path) as f:
        a = json.load(f)
    cfg = get_arch(a["arch"])
    shape = get_shape(a["shape"])
    mem = analytic_memory_bytes(cfg, shape, a["chips"])
    return RooflineTerms(
        arch=a["arch"], shape=a["shape"], mesh=a["mesh"],
        chips=a["chips"], hlo_flops=a["flops_per_device"],
        hbm_bytes=mem["total"],
        collective_bytes=a["collective_bytes_per_device"],
        model_flops=a["model_flops"],
    )
