from repro.analysis.hlo import collective_bytes_from_hlo
from repro.analysis.roofline import RooflineTerms, roofline_from_artifact

__all__ = ["collective_bytes_from_hlo", "RooflineTerms",
           "roofline_from_artifact"]
