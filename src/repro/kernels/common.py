"""Shared kernel plumbing: interpret-mode detection and tiling helpers."""
from __future__ import annotations

import jax


def use_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode off-TPU (this container)."""
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def batch_tile(n: int, elem_bytes: int, *, vmem_budget: int = 8 * 2**20,
               buffers: int = 4, lane: int = 8,
               override: int | None = None) -> int:
    """Largest batch tile keeping ``buffers`` copies of (tile, n) in VMEM.

    VMEM on v5e is ~128 MiB but we budget a small slice so several kernels
    and double-buffered DMA windows coexist; ``lane`` aligns the sublane
    dimension.

    ``override`` short-circuits the heuristic with an explicit tile (the
    autotuner's tuned choice, ``repro.tune``) — validated positive but
    otherwise trusted: the tuner measured it on this device.
    """
    if override is not None:
        if override < 1:
            raise ValueError(f"batch tile override must be >= 1, "
                             f"got {override}")
        return override
    per_row = n * elem_bytes * buffers
    tile = max(vmem_budget // per_row, 1)
    if tile >= lane:
        tile = tile // lane * lane
    return tile
