"""Public wrapper for the harmonic-sum kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import batch_tile, use_interpret
from repro.kernels.harmonic_sum.harmonic_sum_kernel import harmonic_sum_pallas


def harmonic_sum_kernel(power: jax.Array, n_harmonics: int = 32, *,
                        interpret: bool | None = None) -> jax.Array:
    """(..., N) power spectra -> (..., LEVELS, N) harmonic-sum ladder."""
    if interpret is None:
        interpret = use_interpret()
    # A ValueError, not an assert: asserts vanish under ``python -O`` and
    # this guards caller input, not an internal invariant.
    if n_harmonics < 1 or n_harmonics & (n_harmonics - 1):
        raise ValueError(
            f"n_harmonics must be a power of two, got {n_harmonics}")
    power = jnp.asarray(power, jnp.float32)
    lead = power.shape[:-1]
    n = power.shape[-1]
    if n == 0:
        raise ValueError("harmonic_sum_kernel needs a non-empty trailing "
                         f"axis, got shape {power.shape}")
    b = 1
    for d in lead:
        b *= d
    p2 = power.reshape(b, n)
    tile = min(batch_tile(n, 4, buffers=8), b)
    pad = (-b) % tile
    if pad:
        p2 = jnp.pad(p2, ((0, pad), (0, 0)))
    out = harmonic_sum_pallas(p2, n_harmonics, tile_b=tile,
                              interpret=interpret)[:b]
    return out.reshape(*lead, out.shape[-2], n)
