"""Public wrappers for the harmonic-sum kernels.

Two entry points share one guarded input path:

* :func:`harmonic_sum_kernel` — the demo ladder: (..., N) power spectra
  to the full (..., LEVELS, N) doubling ladder (Sec. 5.3 figure fodder).
* :func:`harmonic_sum_plane` — the production pipeline stage: the same
  ladder built, normalised and max-reduced inside VMEM, returning only
  the (..., N) best detection statistic and its level index — the
  (LEVELS, N) ladder never round-trips through HBM.

Edge cases (tested in tests/test_kernels.py):

* ``n_harmonics=1`` is valid: a single-level ladder — the demo returns
  the input as its one level, the plane returns  z_1 = P - 1  with level
  index 0 everywhere.
* An empty trailing axis (shape (..., 0)) raises ``ValueError``: a
  zero-length spectrum has no bins to sum (and the kernel's grid maths
  would divide by zero).
* Complex input raises ``ValueError`` — power spectra are real by
  construction; silently taking ``.real`` would hide an upstream bug
  (pass ``|X|**2``, not the spectrum itself).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import batch_tile, use_interpret
from repro.kernels.harmonic_sum.harmonic_sum_kernel import (
    harmonic_sum_pallas, harmonic_sum_plane_pallas)
from repro.obs.ledger import record_launch


def _checked_power(power, n_harmonics: int, fn_name: str) -> jax.Array:
    """Shared shape/dtype guards -> the (..., N) f32 power array.

    ValueErrors, not asserts: asserts vanish under ``python -O`` and
    these guard caller input, not internal invariants.
    """
    if n_harmonics < 1 or n_harmonics & (n_harmonics - 1):
        raise ValueError(
            f"n_harmonics must be a power of two, got {n_harmonics}")
    power = jnp.asarray(power)
    if jnp.issubdtype(power.dtype, jnp.complexfloating):
        raise ValueError(
            f"{fn_name} takes real power (|X|**2), got complex dtype "
            f"{power.dtype} with shape {power.shape}")
    if power.ndim < 1 or power.shape[-1] == 0:
        raise ValueError(
            f"{fn_name} needs a non-empty trailing axis, got shape "
            f"{power.shape}")
    return power.astype(jnp.float32)


def _tiled(power: jax.Array) -> tuple[jax.Array, int, int, tuple[int, ...]]:
    """Flatten lead dims and pad the batch to a VMEM-sized tile multiple."""
    lead = power.shape[:-1]
    n = power.shape[-1]
    b = 1
    for d in lead:
        b *= d
    p2 = power.reshape(b, n)
    tile = min(batch_tile(n, 4, buffers=8), b)
    pad = (-b) % tile
    if pad:
        p2 = jnp.pad(p2, ((0, pad), (0, 0)))
    return p2, b, tile, lead


def harmonic_sum_kernel(power: jax.Array, n_harmonics: int = 32, *,
                        interpret: bool | None = None) -> jax.Array:
    """(..., N) power spectra -> (..., LEVELS, N) harmonic-sum ladder."""
    if interpret is None:
        interpret = use_interpret()
    power = _checked_power(power, n_harmonics, "harmonic_sum_kernel")
    p2, b, tile, lead = _tiled(power)
    out = harmonic_sum_pallas(p2, n_harmonics, tile_b=tile,
                              interpret=interpret)[:b]
    n = power.shape[-1]
    record_launch("harmonic-sum", grid=(p2.shape[0] // tile,),
                  tile=(tile, n),
                  bytes_moved=4 * p2.shape[0] * n * (1 + out.shape[-2]),
                  shape=(b, n))
    return out.reshape(*lead, out.shape[-2], power.shape[-1])


def harmonic_sum_plane(power: jax.Array, n_harmonics: int = 8, *,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """(..., N) power plane -> ((..., N) statistic, (..., N) int32 level).

    The statistic is  max_h (S_h - h) / sqrt(h)  over the doubling
    ladder h = 1, 2, ..., n_harmonics, valid for planes normalised to
    per-bin mean 1 under the null (the FDAS power plane); ``level`` is
    log2(h) of the winning ladder rung (earliest wins ties).
    """
    if interpret is None:
        interpret = use_interpret()
    power = _checked_power(power, n_harmonics, "harmonic_sum_plane")
    p2, b, tile, lead = _tiled(power)
    stat, lev = harmonic_sum_plane_pallas(p2, n_harmonics, tile_b=tile,
                                          interpret=interpret)
    n = power.shape[-1]
    record_launch("harmonic-sum-plane", grid=(p2.shape[0] // tile,),
                  tile=(tile, n), bytes_moved=12 * p2.shape[0] * n,
                  shape=(b, n))
    return stat[:b].reshape(*lead, n), lev[:b].reshape(*lead, n)
