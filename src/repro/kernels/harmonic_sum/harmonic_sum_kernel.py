"""Harmonic-sum Pallas kernel — gather-free decimate-and-add.

GPU pulsar pipelines implement S_h[k] = sum_j P[j*k] with texture/global
gathers; TPU has no efficient gather, so we ADAPT the algorithm
(DESIGN.md: rethink for the TPU memory hierarchy):

  P[j*k] over k = 0..ceil(N/j)-1  ==  the stride-j decimation  P[::j]

which is an affine ``lax.slice`` — no gather at all.  Each doubling level
adds h/2 freshly decimated, zero-padded copies of the VMEM-resident
spectrum, so level h costs h/2 strided reads of a tile that was loaded
from HBM exactly once.  Output is the (TILE_B, LEVELS, N) ladder
(h = 1, 2, 4, ..., H).

Grid: 1-D over batch tiles; the whole spectrum row stays in VMEM because
harmonic k reaches j*k far beyond any k-tile (k-tiling would need almost
the entire row anyway — this is the VMEM-vs-HBM trade the paper's Sec. 5
discussion about overhead accesses t_o maps onto).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decimate(p: jax.Array, j: int) -> jax.Array:
    """P[:, ::j] zero-padded back to full length (B, N)."""
    b, n = p.shape
    if j == 1:
        return p
    m = (n + j - 1) // j
    q = jax.lax.slice(p, (0, 0), (b, (m - 1) * j + 1), (1, j))   # (B, m)
    return jnp.pad(q, ((0, 0), (0, n - m)))


def _hsum_body(p_ref, out_ref, *, n_harmonics: int):
    p = p_ref[...]                                   # (B, N)
    levels = int(math.log2(n_harmonics)) + 1
    acc = p
    out_ref[:, 0, :] = acc
    h = 1
    for lev in range(1, levels):
        h *= 2
        for j in range(h // 2 + 1, h + 1):
            acc = acc + _decimate(p, j)
        out_ref[:, lev, :] = acc


def _hsum_plane_body(p_ref, stat_ref, lev_ref, *, n_harmonics: int):
    """Fused ladder + normalisation + best-level reduction.

    The production pipeline path: builds the same doubling ladder as
    ``_hsum_body`` but never writes it — each level is normalised in
    VMEM to the detection statistic  z_h = (S_h - h) / sqrt(h)  (the
    FDAS power plane is ~chi^2(2)/2 under the null, per-bin mean 1) and
    max-reduced on the spot.  Only the (B, N) winning statistic and its
    (B, N) level index leave VMEM: the (LEVELS, N) ladder of the demo
    kernel never makes an HBM round-trip.
    """
    p = p_ref[...]                                   # (B, N)
    levels = int(math.log2(n_harmonics)) + 1
    acc = p
    best = acc - 1.0                                 # z_1 = S_1 - 1
    best_lev = jnp.zeros(p.shape, jnp.int32)
    h = 1
    for lev in range(1, levels):
        h *= 2
        for j in range(h // 2 + 1, h + 1):
            acc = acc + _decimate(p, j)
        z = (acc - h) * (1.0 / math.sqrt(h))
        better = z > best
        best = jnp.where(better, z, best)
        best_lev = jnp.where(better, lev, best_lev)
    stat_ref[...] = best
    lev_ref[...] = best_lev


@functools.partial(jax.jit,
                   static_argnames=("n_harmonics", "tile_b", "interpret"))
def harmonic_sum_plane_pallas(power: jax.Array, n_harmonics: int, *,
                              tile_b: int = 8, interpret: bool = False):
    """(b, n) power -> ((b, n) best statistic, (b, n) int32 level)."""
    b, n = power.shape
    if tile_b < 1 or b % tile_b:
        raise ValueError(
            f"batch={b} is not a multiple of its tile ({tile_b}); the ops "
            f"layer (repro.kernels.harmonic_sum.ops) pads batches to tile "
            f"multiples — route through it or pass a dividing tile")
    fn = pl.pallas_call(
        functools.partial(_hsum_plane_body, n_harmonics=n_harmonics),
        grid=(b // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, n), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile_b, n), lambda i: (i, 0)),
                   pl.BlockSpec((tile_b, n), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, n), power.dtype),
                   jax.ShapeDtypeStruct((b, n), jnp.int32)],
        interpret=interpret,
    )
    return tuple(fn(power))


@functools.partial(jax.jit,
                   static_argnames=("n_harmonics", "tile_b", "interpret"))
def harmonic_sum_pallas(power: jax.Array, n_harmonics: int, *,
                        tile_b: int = 8, interpret: bool = False):
    b, n = power.shape
    # A ValueError, not an assert: asserts vanish under ``python -O`` and
    # a non-dividing tile would silently corrupt the grid partition.
    if tile_b < 1 or b % tile_b:
        raise ValueError(
            f"batch={b} is not a multiple of its tile ({tile_b}); the ops "
            f"layer (repro.kernels.harmonic_sum.ops) pads batches to tile "
            f"multiples — route through it or pass a dividing tile")
    levels = int(math.log2(n_harmonics)) + 1
    fn = pl.pallas_call(
        functools.partial(_hsum_body, n_harmonics=n_harmonics),
        grid=(b // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_b, levels, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, levels, n), power.dtype),
        interpret=interpret,
    )
    return fn(power)
