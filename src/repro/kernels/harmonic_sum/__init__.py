from repro.kernels.harmonic_sum.ops import harmonic_sum_kernel

__all__ = ["harmonic_sum_kernel"]
