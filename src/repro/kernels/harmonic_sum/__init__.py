from repro.kernels.harmonic_sum.ops import (harmonic_sum_kernel,
                                            harmonic_sum_plane)
from repro.kernels.harmonic_sum.ref import (harmonic_sum_plane_ref,
                                            harmonic_sum_ref)

__all__ = ["harmonic_sum_kernel", "harmonic_sum_plane",
           "harmonic_sum_plane_ref", "harmonic_sum_ref"]
