"""Pure-jnp oracle for the harmonic-sum kernel.

Definition (zero-padded convention — see kernel docstring):

  S_h[k] = sum_{j=1..h} P[j*k]   with P[i] = 0 for i >= N

Output levels h = 1, 2, 4, ..., n_harmonics (the standard pulsar-search
doubling ladder).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def harmonic_sum_ref(power: jax.Array, n_harmonics: int) -> jax.Array:
    n = power.shape[-1]
    levels = int(math.log2(n_harmonics)) + 1
    k = jnp.arange(n)
    outs = []
    acc = power
    outs.append(acc)
    h = 1
    for _ in range(levels - 1):
        h *= 2
        js = jnp.arange(h // 2 + 1, h + 1)
        idx = js[:, None] * k[None, :]                     # (h/2, n)
        valid = idx < n
        gathered = jnp.where(valid, power[..., jnp.minimum(idx, n - 1)], 0.0)
        acc = acc + jnp.sum(gathered, axis=-2)
        outs.append(acc)
    return jnp.stack(outs, axis=-2)


def harmonic_sum_plane_ref(power: jax.Array, n_harmonics: int):
    """Oracle for the fused plane kernel: (best statistic, level index).

    Normalises every ladder level to  z_h = (S_h - h) / sqrt(h)  and
    takes the maximum (earliest level wins ties, matching the kernel's
    strict ``z > best`` update).
    """
    ladder = harmonic_sum_ref(power, n_harmonics)          # (..., L, n)
    levels = ladder.shape[-2]
    hs = jnp.asarray([2.0 ** lev for lev in range(levels)])
    z = (ladder - hs[:, None]) / jnp.sqrt(hs)[:, None]
    best_lev = jnp.argmax(z, axis=-2).astype(jnp.int32)
    best = jnp.max(z, axis=-2)
    return best, best_lev
