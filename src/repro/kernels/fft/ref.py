"""Pure-jnp oracles for the FFT kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fft_ref(re: jax.Array, im: jax.Array, *, inverse: bool = False):
    """Reference via jnp.fft on the recombined complex array."""
    x = re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64)
    y = jnp.fft.ifft(x, axis=-1) if inverse else jnp.fft.fft(x, axis=-1)
    return y.real.astype(re.dtype), y.imag.astype(im.dtype)


def rfft_ref(x: jax.Array):
    """R2C reference: (..., n) real -> (..., n/2+1) re/im planes."""
    y = jnp.fft.rfft(x.astype(jnp.float32), axis=-1)
    return y.real.astype(jnp.float32), y.imag.astype(jnp.float32)


def irfft_ref(re: jax.Array, im: jax.Array):
    """C2R reference: (..., n/2+1) re/im planes -> (..., n) real."""
    x = re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64)
    return jnp.fft.irfft(x, axis=-1).astype(jnp.float32)
