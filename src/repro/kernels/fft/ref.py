"""Pure-jnp oracle for the FFT kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fft_ref(re: jax.Array, im: jax.Array, *, inverse: bool = False):
    """Reference via jnp.fft on the recombined complex array."""
    x = re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64)
    y = jnp.fft.ifft(x, axis=-1) if inverse else jnp.fft.fft(x, axis=-1)
    return y.real.astype(re.dtype), y.imag.astype(im.dtype)
