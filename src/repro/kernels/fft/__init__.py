from repro.kernels.fft.ops import (MAX_KERNEL_N, fft_kernel_c2c,
                                   fft_kernel_c2c_axis1,
                                   fft_kernel_c2c_mul,
                                   fft_kernel_c2c_t, fft_kernel_c2r,
                                   fft_kernel_r2c, fft_kernel_r2c_t,
                                   transpose_kernel)

__all__ = ["MAX_KERNEL_N", "fft_kernel_c2c", "fft_kernel_c2c_axis1", "fft_kernel_r2c",
           "fft_kernel_c2c_mul", "fft_kernel_c2r", "fft_kernel_c2c_t",
           "fft_kernel_r2c_t", "transpose_kernel"]
