from repro.kernels.fft.ops import (MAX_KERNEL_N, fft_kernel_c2c,
                                   fft_kernel_c2r, fft_kernel_r2c)

__all__ = ["MAX_KERNEL_N", "fft_kernel_c2c", "fft_kernel_r2c",
           "fft_kernel_c2r"]
