from repro.kernels.fft.ops import fft_kernel_c2c

__all__ = ["fft_kernel_c2c"]
