"""Public jit'd wrapper for the fused Stockham FFT kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import batch_tile, use_interpret
from repro.kernels.fft.fft_kernel import fft_pallas

# One fused pass handles transforms that fit VMEM alongside work buffers.
MAX_KERNEL_N = 2**13


def fft_kernel_c2c(x: jax.Array, *, inverse: bool = False,
                   interpret: bool | None = None) -> jax.Array:
    """Batched pow2 C2C FFT (..., N) via the Pallas kernel.

    Accepts complex input, splits to re/im planes for the kernel, and
    recombines.  Longer-than-VMEM transforms should go through
    ``repro.fft.plan`` (four-step built on this kernel per pass).
    """
    if interpret is None:
        interpret = use_interpret()
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    assert n <= MAX_KERNEL_N, (
        f"N={n} exceeds the single-pass kernel; use repro.fft.plan")
    lead = x.shape[:-1]
    b = 1
    for d in lead:
        b *= d
    re = x.real.reshape(b, n).astype(jnp.float32)
    im = x.imag.reshape(b, n).astype(jnp.float32)

    tile = min(batch_tile(n, 4, buffers=6), b)
    # pad batch to a tile multiple
    pad = (-b) % tile
    if pad:
        re = jnp.pad(re, ((0, pad), (0, 0)))
        im = jnp.pad(im, ((0, pad), (0, 0)))
    out_re, out_im = fft_pallas(re, im, tile_b=tile, inverse=inverse,
                                interpret=interpret)
    out = out_re[:b] + 1j * out_im[:b]
    return out.reshape(*lead, n)
