"""Public jit'd wrappers for the fused mixed-radix Stockham FFT kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fft.radix import DEFAULT_RADICES
from repro.kernels.common import batch_tile, use_interpret
from repro.obs.ledger import record_launch
from repro.kernels.fft.fft_kernel import (fft_axis1_pallas,
                                          fft_axis1_twiddle_pallas,
                                          fft_mul_pallas, fft_pallas,
                                          fft_t_pallas,
                                          fft_t_twiddle_pallas, irfft_pallas,
                                          rfft_pallas, rfft_t_pallas,
                                          transpose_pallas)

# One fused pass handles transforms that fit VMEM alongside work buffers.
MAX_KERNEL_N = 2**13


def _check_kernel_length(n: int) -> None:
    if n > MAX_KERNEL_N:
        raise ValueError(
            f"N={n} exceeds the single-pass kernel limit ({MAX_KERNEL_N}); "
            "route long transforms through repro.fft.plan (its four-step "
            "decomposition runs this kernel once per pow2 pass)")


def _flatten(x: jax.Array) -> tuple[jax.Array, tuple[int, ...], int]:
    """Collapse leading dims to one batch axis: (..., n) -> (b, n)."""
    lead = x.shape[:-1]
    b = 1
    for d in lead:
        b *= d
    return x.reshape(b, x.shape[-1]), lead, b


def _tile_and_pad(planes: list[jax.Array], b: int, n: int,
                  elem_bytes: int = 4,
                  tile_b: int | None = None) -> tuple[list[jax.Array], int]:
    """Pick a batch tile and pad only when the batch is not a multiple.

    A tile-multiple batch (the common case after the serving layer's
    coalescer) skips the pad-then-slice HBM round trip entirely.
    ``tile_b`` is an explicit override (the autotuner's tuned choice,
    clamped to the batch) — when None the VMEM-budget heuristic decides.
    """
    tile = min(batch_tile(n, elem_bytes, buffers=8, override=tile_b), b)
    pad = (-b) % tile
    if pad:
        planes = [jnp.pad(p, ((0, pad), (0, 0))) for p in planes]
    return planes, tile


def fft_kernel_c2c(x: jax.Array, *, inverse: bool = False,
                   interpret: bool | None = None,
                   radices: tuple[int, ...] = DEFAULT_RADICES,
                   tile_b: int | None = None) -> jax.Array:
    """Batched pow2 C2C FFT (..., N) via the Pallas kernel.

    Accepts complex input, splits to re/im planes for the kernel, and
    recombines.  Longer-than-VMEM transforms should go through
    ``repro.fft.plan`` (four-step built on this kernel per pass).
    ``tile_b`` overrides the heuristic batch tile (autotuner hook).
    """
    if interpret is None:
        interpret = use_interpret()
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    _check_kernel_length(n)
    if n == 1:
        # The length-1 DFT is the identity BOTH ways: the forward sum is
        # the single point and the inverse normalisation is 1/1, so the
        # old ``x / 1`` "inverse" was a silent no-op copy.
        return x
    flat, lead, b = _flatten(x)
    re = flat.real.astype(jnp.float32)
    im = flat.imag.astype(jnp.float32)
    (re, im), tile = _tile_and_pad([re, im], b, n, tile_b=tile_b)
    out_re, out_im = fft_pallas(re, im, tile_b=tile, inverse=inverse,
                                interpret=interpret, radices=radices)
    padded = b + (-b) % tile
    record_launch("fft-c2c", grid=(padded // tile,), tile=(tile, n),
                  bytes_moved=16 * padded * n, shape=(b, n))
    if out_re.shape[0] != b:
        out_re, out_im = out_re[:b], out_im[:b]
    return (out_re + 1j * out_im).reshape(*lead, n)


def fft_kernel_c2c_mul(x: jax.Array, bank, *, inverse: bool = False,
                       interpret: bool | None = None,
                       radices: tuple[int, ...] = DEFAULT_RADICES,
                       tile_b: int | None = None) -> jax.Array:
    """Fused pow2 C2C FFT + (T, N) filter-bank multiply epilogue.

    (..., N) in -> (..., T, N) out with out[..., t, :] = FFT(x) * bank[t].
    The bank multiply happens in VMEM on the transformed tile — the
    matched-filter plane of a T-template search costs one forward pass
    (this kernel) plus T inverse passes, with no standalone multiply
    pass.  ``bank`` is a host-side (T, N) complex array (the cached
    filter spectra of ``repro.fft.convolve``).
    """
    if interpret is None:
        interpret = use_interpret()
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    _check_kernel_length(n)
    bank = jnp.asarray(bank)
    if bank.ndim != 2 or bank.shape[-1] != n:
        raise ValueError(
            f"filter bank must be (T, {n}), got {bank.shape}")
    t = bank.shape[0]
    fbr = bank.real.astype(jnp.float32)
    fbi = bank.imag.astype(jnp.float32)
    flat, lead, b = _flatten(x)
    re = flat.real.astype(jnp.float32)
    im = flat.imag.astype(jnp.float32)
    # The output plane is T x the input tile; scale the VMEM budget so
    # input, bank and product planes coexist.
    (re, im), tile = _tile_and_pad([re, im], b, n * (4 + 2 * t) // 8,
                                   tile_b=tile_b)
    out_re, out_im = fft_mul_pallas(re, im, fbr, fbi, tile_b=tile,
                                    inverse=inverse, interpret=interpret,
                                    radices=radices)
    padded = b + (-b) % tile
    record_launch("fft-c2c-mul", grid=(padded // tile,), tile=(tile, n),
                  bytes_moved=8 * n * (padded + t + padded * t),
                  shape=(b, t, n))
    if out_re.shape[0] != b:
        out_re, out_im = out_re[:b], out_im[:b]
    return (out_re + 1j * out_im).reshape(*lead, t, n)


def _row_tile(r: int, c: int, elem_bytes: int = 4, buffers: int = 10,
              override: int | None = None) -> int:
    """Largest row tile that divides ``r`` and fits the VMEM budget.

    A divisor search (not pow2 halving): ``batch_tile`` returns
    lane-aligned but often non-pow2 budgets, and halving those would
    collapse to tile=1 for the pow2 row counts the fused passes serve.
    An explicit ``override`` (the autotuner's tile) is snapped down to
    the nearest divisor of ``r`` the same way.
    """
    tile = max(min(batch_tile(c, elem_bytes, buffers=buffers,
                              override=override), r), 1)
    while tile > 1 and r % tile:
        tile -= 1
    return tile


def _flatten3(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    """Collapse leading dims to one batch axis: (..., R, C) -> (b, R, C)."""
    lead = x.shape[:-2]
    b = 1
    for d in lead:
        b *= d
    return x.reshape(b, *x.shape[-2:]), lead


def fft_kernel_c2c_t(x: jax.Array, *, twiddle=None, inverse: bool = False,
                     interpret: bool | None = None,
                     radices: tuple[int, ...] = DEFAULT_RADICES,
                     tile_b: int | None = None) -> jax.Array:
    """Fused C2C FFT + transposed write: (..., R, C) -> (..., C, R).

    The hand-off transpose of a 2-D / N-D / four-step transform rides the
    FFT pass: each (tile_r, C) row tile is transformed in VMEM and written
    into its (C, tile_r) column window — one HBM read + one write total.

    ``twiddle`` (optional, an (R, C) complex table) fuses the four-step
    inter-pass multiply as a kernel epilogue, deleting the separate XLA
    multiply pass of the unfused path.
    """
    if interpret is None:
        interpret = use_interpret()
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    r, c = x.shape[-2:]
    _check_kernel_length(c)
    flat, lead = _flatten3(x)
    re = flat.real.astype(jnp.float32)
    im = flat.imag.astype(jnp.float32)
    tile = _row_tile(r, c, override=tile_b)
    if twiddle is not None:
        tw = jnp.asarray(twiddle)
        ftwr = tw.real.astype(jnp.float32)
        ftwi = tw.imag.astype(jnp.float32)
        out_re, out_im = fft_t_twiddle_pallas(
            re, im, ftwr, ftwi, tile_r=tile, inverse=inverse,
            interpret=interpret, radices=radices)
    else:
        out_re, out_im = fft_t_pallas(re, im, tile_r=tile, inverse=inverse,
                                      interpret=interpret, radices=radices)
    record_launch("fft-c2c-t", grid=(flat.shape[0], r // tile),
                  tile=(tile, c), bytes_moved=16 * flat.shape[0] * r * c,
                  shape=(flat.shape[0], r, c))
    return (out_re + 1j * out_im).reshape(*lead, c, r)


def fft_kernel_c2c_axis1(x: jax.Array, *, twiddle=None,
                         inverse: bool = False,
                         interpret: bool | None = None,
                         radices: tuple[int, ...] = DEFAULT_RADICES,
                         tile_b: int | None = None) -> jax.Array:
    """C2C FFT over axis -2, layout preserved: (..., R, C) -> (..., R, C).

    The four-step column pass: transpose-read + FFT + optional twiddle
    epilogue + transpose-write, all in VMEM (one HBM round trip).
    ``twiddle`` is a (C, R) complex table; output ``[..., k, j]`` is
    multiplied by ``twiddle[j, k]``.
    """
    if interpret is None:
        interpret = use_interpret()
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    r, c = x.shape[-2:]
    _check_kernel_length(r)
    flat, lead = _flatten3(x)
    re = flat.real.astype(jnp.float32)
    im = flat.imag.astype(jnp.float32)
    tile = _row_tile(c, r, override=tile_b)
    if twiddle is not None:
        tw = jnp.asarray(twiddle)
        ftwr = tw.real.astype(jnp.float32)
        ftwi = tw.imag.astype(jnp.float32)
        out_re, out_im = fft_axis1_twiddle_pallas(
            re, im, ftwr, ftwi, tile_c=tile, inverse=inverse,
            interpret=interpret, radices=radices)
    else:
        out_re, out_im = fft_axis1_pallas(re, im, tile_c=tile,
                                          inverse=inverse,
                                          interpret=interpret,
                                          radices=radices)
    record_launch("fft-c2c-axis1", grid=(flat.shape[0], c // tile),
                  tile=(r, tile), bytes_moved=16 * flat.shape[0] * r * c,
                  shape=(flat.shape[0], r, c))
    return (out_re + 1j * out_im).reshape(*lead, r, c)


def fft_kernel_r2c_t(x: jax.Array, *, interpret: bool | None = None,
                     radices: tuple[int, ...] = DEFAULT_RADICES,
                     tile_b: int | None = None) -> jax.Array:
    """Fused R2C + transposed write: (..., R, C) real -> (..., C/2+1, R)."""
    if interpret is None:
        interpret = use_interpret()
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.real
    r, c = x.shape[-2:]
    _check_kernel_length(max(c // 2, 1))
    if c < 4:
        raise ValueError(f"fused R2C needs C >= 4, got {c}")
    flat, lead = _flatten3(x.astype(jnp.float32))
    tile = _row_tile(r, c, override=tile_b)
    out_re, out_im = rfft_t_pallas(flat, tile_r=tile, interpret=interpret,
                                   radices=radices)
    record_launch(
        "fft-r2c-t", grid=(flat.shape[0], r // tile), tile=(tile, c),
        bytes_moved=4 * flat.shape[0] * r * (c + 2 * (c // 2 + 1)),
        shape=(flat.shape[0], r, c))
    return (out_re + 1j * out_im).reshape(*lead, c // 2 + 1, r)


def transpose_kernel(x: jax.Array, *,
                     interpret: bool | None = None) -> jax.Array:
    """Tiled last-two-axes transpose: (..., R, C) -> (..., C, R), one pass.

    Complex inputs travel as split re/im planes (TPU Pallas wants real
    dtypes); each plane is transposed tile by tile in VMEM.
    """
    if interpret is None:
        interpret = use_interpret()
    x = jnp.asarray(x)
    r, c = x.shape[-2:]
    flat, lead = _flatten3(x)
    tr = _row_tile(r, max(c, 1), buffers=4)
    tc = _row_tile(c, max(r, 1), buffers=4)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        re, im = transpose_pallas(flat.real, flat.imag, tile_r=tr, tile_c=tc,
                                  interpret=interpret)
        record_launch("transpose", grid=(flat.shape[0], r // tr, c // tc),
                      tile=(tr, tc),
                      bytes_moved=2 * flat.shape[0] * r * c * x.dtype.itemsize,
                      shape=(flat.shape[0], r, c))
        return (re + 1j * im).astype(x.dtype).reshape(*lead, c, r)
    (out,) = transpose_pallas(flat, tile_r=tr, tile_c=tc, interpret=interpret)
    record_launch("transpose", grid=(flat.shape[0], r // tr, c // tc),
                  tile=(tr, tc),
                  bytes_moved=2 * flat.shape[0] * r * c * x.dtype.itemsize,
                  shape=(flat.shape[0], r, c))
    return out.reshape(*lead, c, r)


def fft_kernel_r2c(x: jax.Array, *, interpret: bool | None = None,
                   radices: tuple[int, ...] = DEFAULT_RADICES,
                   tile_b: int | None = None) -> jax.Array:
    """Batched pow2 R2C FFT: (..., N) real -> (..., N/2+1) complex.

    Packs N reals as N/2 complex points, so it accepts N up to
    2 * MAX_KERNEL_N; the Hermitian split runs fused inside the kernel.
    """
    if interpret is None:
        interpret = use_interpret()
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.real
    n = x.shape[-1]
    _check_kernel_length(max(n // 2, 1))
    if n < 4:
        from repro.fft.stockham import rfft
        return rfft(x)
    flat, lead, b = _flatten(x.astype(jnp.float32))
    (flat,), tile = _tile_and_pad([flat], b, n, tile_b=tile_b)
    out_re, out_im = rfft_pallas(flat, tile_b=tile, interpret=interpret,
                                 radices=radices)
    padded = b + (-b) % tile
    record_launch("fft-r2c", grid=(padded // tile,), tile=(tile, n),
                  bytes_moved=4 * padded * (n + 2 * (n // 2 + 1)),
                  shape=(b, n))
    if out_re.shape[0] != b:
        out_re, out_im = out_re[:b], out_im[:b]
    return (out_re + 1j * out_im).reshape(*lead, n // 2 + 1)


def fft_kernel_c2r(x: jax.Array, *, interpret: bool | None = None,
                   radices: tuple[int, ...] = DEFAULT_RADICES,
                   tile_b: int | None = None) -> jax.Array:
    """Batched pow2 C2R inverse: (..., N/2+1) half-spectrum -> (..., N) real.

    The exact inverse of :func:`fft_kernel_r2c` (1/N normalised, matching
    ``jnp.fft.irfft``).
    """
    if interpret is None:
        interpret = use_interpret()
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    m = x.shape[-1] - 1
    n = 2 * m
    _check_kernel_length(max(m, 1))
    if n < 4:
        from repro.fft.stockham import irfft
        return irfft(x)
    flat, lead, b = _flatten(x)
    re = flat.real.astype(jnp.float32)
    im = flat.imag.astype(jnp.float32)
    (re, im), tile = _tile_and_pad([re, im], b, n, tile_b=tile_b)
    out = irfft_pallas(re, im, tile_b=tile, interpret=interpret,
                       radices=radices)
    padded = b + (-b) % tile
    record_launch("fft-c2r", grid=(padded // tile,), tile=(tile, n),
                  bytes_moved=4 * padded * (2 * (m + 1) + n),
                  shape=(b, n))
    if out.shape[0] != b:
        out = out[:b]
    return out.reshape(*lead, n)
