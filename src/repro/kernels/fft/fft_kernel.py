"""Fused-stage Stockham FFT Pallas kernel.

TPU adaptation of the paper's single-kernel cuFFT plans (DESIGN.md Sec. 3):
instead of a threadblock exchanging butterflies through shared memory, one
Pallas program instance keeps a (TILE_B, N) tile of transforms resident in
VMEM and runs **all** log2(N) Stockham stages before writing back.  HBM
traffic is exactly one read + one write of the batch — the paper's ideal
``t_i``-only case (Sec. 5: t_fix = t_i + t_o with t_o -> 0).

Layout notes:
  * complex data travels as separate (re, im) float32 arrays — TPU Pallas
    vector memory wants real dtypes, and splitting re/im keeps every
    butterfly a pure VPU elementwise op with no interleave shuffles;
  * each stage reshapes the tile (TILE_B, L, M) -> split M -> stack; all
    affine, no gathers (the Stockham property), so Mosaic lowers them to
    vreg moves;
  * twiddles are recomputed per stage with iota/cos/sin rather than loaded,
    trading cheap VPU transcendentals for HBM bandwidth (the scarce
    resource — the whole point of the paper is that this kernel is
    memory-bound).

Grid: 1-D over batch tiles.  BlockSpec pins a (TILE_B, N) window in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stockham_stages(re, im, n: int, *, inverse: bool):
    """Run all radix-2 Stockham DIF stages on a (B, N) re/im tile pair."""
    b = re.shape[0]
    sign = 1.0 if inverse else -1.0
    re = re.reshape(b, 1, n)
    im = im.reshape(b, 1, n)
    l, m = 1, n
    while m > 1:
        h = m // 2
        ar, ai = re[..., :h], im[..., :h]
        br, bi = re[..., h:], im[..., h:]
        # twiddle w_j = exp(sign * i*pi*j/h), j broadcast over (B, L, h)
        j = jax.lax.broadcasted_iota(jnp.float32, (b, l, h), 2)
        ang = sign * jnp.pi * j / h
        wr, wi = jnp.cos(ang), jnp.sin(ang)
        er, ei = ar + br, ai + bi                  # even outputs
        dr, di = ar - br, ai - bi
        orr = dr * wr - di * wi                    # odd = (a-b) * w
        oi = dr * wi + di * wr
        re = jnp.stack([er, orr], axis=1).reshape(b, 2 * l, h)
        im = jnp.stack([ei, oi], axis=1).reshape(b, 2 * l, h)
        l, m = 2 * l, h
    re = re.reshape(b, n)
    im = im.reshape(b, n)
    if inverse:
        re, im = re / n, im / n
    return re, im


def _fft_body(re_ref, im_ref, out_re_ref, out_im_ref, *, n: int,
              inverse: bool):
    re = re_ref[...]
    im = im_ref[...]
    out_re, out_im = _stockham_stages(re, im, n, inverse=inverse)
    out_re_ref[...] = out_re
    out_im_ref[...] = out_im


@functools.partial(jax.jit,
                   static_argnames=("tile_b", "inverse", "interpret"))
def fft_pallas(re: jax.Array, im: jax.Array, *, tile_b: int = 8,
               inverse: bool = False, interpret: bool = False):
    """Batched pow2 C2C FFT over the last axis; (B, N) re/im in, same out."""
    b, n = re.shape
    assert n & (n - 1) == 0, f"pow2 lengths only, got {n}"
    assert b % tile_b == 0, (b, tile_b)
    grid = (b // tile_b,)
    spec = pl.BlockSpec((tile_b, n), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((b, n), re.dtype)] * 2
    fn = pl.pallas_call(
        functools.partial(_fft_body, n=n, inverse=inverse),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(re, im)
