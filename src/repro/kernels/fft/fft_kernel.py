"""Fused-stage mixed-radix Stockham FFT Pallas kernel.

TPU adaptation of the paper's single-kernel cuFFT plans (DESIGN.md Sec. 3):
instead of a threadblock exchanging butterflies through shared memory, one
Pallas program instance keeps a (TILE_B, N) tile of transforms resident in
VMEM and runs **all** Stockham stages before writing back.  HBM traffic is
exactly one read + one write of the batch — the paper's ideal ``t_i``-only
case (Sec. 5: t_fix = t_i + t_o with t_o -> 0).

Layout notes:
  * complex data travels as separate (re, im) float32 arrays — TPU Pallas
    vector memory wants real dtypes, and splitting re/im keeps every
    butterfly a pure VPU elementwise op with no interleave shuffles;
  * each stage reshapes the tile (TILE_B, L, M) -> split M -> stack; all
    affine, no gathers (the Stockham property), so Mosaic lowers them to
    vreg moves;
  * the radix schedule comes from ``repro.fft.radix``: radix-4 stages with
    a radix-2 tail by default (half the stages of the old radix-2 kernel),
    radix-8 available via ``radices=(8, 4, 2)``;
  * twiddles are **precomputed once per length** (host-side, memoised in
    ``repro.fft.radix``) and streamed in as a packed (rows, N) table —
    each grid step reads the table from its pinned VMEM window instead of
    burning VPU transcendentals per stage; inverse transforms conjugate
    the table in-register (negate the im plane);
  * R2C packs N real points as N/2 complex, runs the half-length stage
    pipeline, and applies the Hermitian split *inside the kernel* — one
    HBM read of N floats and one write of N/2+1 complex pairs, ~2x less
    traffic than C2C at the same N.  C2R is the exact mirror.

Grid: 1-D over batch tiles.  BlockSpec pins a (TILE_B, N) data window and
the whole twiddle table in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.fft.radix import (DEFAULT_RADICES, dft_matrix,
                             packed_stage_twiddles, radix_schedule,
                             rfft_split_twiddles)


def _cmul(ar, ai, br, bi):
    """Complex multiply on split planes: (ar + i*ai) * (br + i*bi)."""
    return ar * br - ai * bi, ar * bi + ai * br


def _require_pow2(n: int, what: str, minimum: int = 1) -> None:
    """ValueError, not assert: asserts vanish under ``python -O`` and turn
    malformed launches into silent corruption inside the kernel."""
    if n < 1 or n & (n - 1):
        raise ValueError(f"{what} must be a power of two, got {n}")
    if n < minimum:
        raise ValueError(f"{what} must be >= {minimum}, got {n}")


def _require_tiled(size: int, tile: int, axis: str) -> None:
    if tile < 1 or size % tile:
        raise ValueError(
            f"{axis}={size} is not a multiple of its tile ({tile}); the "
            f"ops layer (repro.kernels.fft.ops) pads batches to tile "
            f"multiples — route through it or pass a dividing tile")


def _mixed_radix_stages(re, im, n: int, twr, twi, *,
                        radices: tuple[int, ...], inverse: bool):
    """Run the full radix schedule on a (B, N) re/im tile pair.

    ``twr``/``twi`` is the packed forward twiddle table from
    :func:`repro.fft.radix.packed_stage_twiddles`; inverse transforms
    negate the im plane (conjugation) in-register.
    """
    b = re.shape[0]
    if n == 1:
        return re, im
    sign = 1.0 if inverse else -1.0
    if inverse:
        twi = -twi
    re = re.reshape(b, 1, n)
    im = im.reshape(b, 1, n)
    l, m, row = 1, n, 0
    for r in radix_schedule(n, radices):
        h = m // r
        ws = [(twr[row + k, :h].reshape(1, 1, h),
               twi[row + k, :h].reshape(1, 1, h)) for k in range(r - 1)]
        parts = [(re[..., p * h:(p + 1) * h], im[..., p * h:(p + 1) * h])
                 for p in range(r)]
        if r == 2:
            (ar, ai), (br, bi) = parts
            outs = [(ar + br, ai + bi)]
            branches = [(ar - br, ai - bi)]
        elif r == 4:
            (x0r, x0i), (x1r, x1i), (x2r, x2i), (x3r, x3i) = parts
            t0r, t0i = x0r + x2r, x0i + x2i
            t1r, t1i = x0r - x2r, x0i - x2i
            t2r, t2i = x1r + x3r, x1i + x3i
            t3r, t3i = x1r - x3r, x1i - x3i
            # b1/b3 = t1 -+ i*t3 (forward); sign flips for the inverse.
            u3r, u3i = -sign * t3i, sign * t3r          # sign * i * t3
            outs = [(t0r + t2r, t0i + t2i)]
            branches = [(t1r + u3r, t1i + u3i),
                        (t0r - t2r, t0i - t2i),
                        (t1r - u3r, t1i - u3i)]
        else:
            # Generic butterfly via the radix-r DFT matrix (radix-8 path).
            dft = dft_matrix(r, inverse)
            outs = [(functools.reduce(lambda a, p: a + p[0],
                                      parts[1:], parts[0][0]),
                     functools.reduce(lambda a, p: a + p[1],
                                      parts[1:], parts[0][1]))]
            branches = []
            for k in range(1, r):
                accr, acci = parts[0]
                for p in range(1, r):
                    cr, ci = float(dft[p, k].real), float(dft[p, k].imag)
                    pr, pi = parts[p]
                    accr = accr + pr * cr - pi * ci
                    acci = acci + pr * ci + pi * cr
                branches.append((accr, acci))
        for k, (vr, vi) in enumerate(branches):
            wr, wi = ws[k]
            outs.append(_cmul(vr, vi, wr, wi))
        re = jnp.stack([o[0] for o in outs], axis=1).reshape(b, r * l, h)
        im = jnp.stack([o[1] for o in outs], axis=1).reshape(b, r * l, h)
        row += r - 1
        l, m = r * l, h
    re = re.reshape(b, n)
    im = im.reshape(b, n)
    if inverse:
        re, im = re / n, im / n
    return re, im


def _c2c_body(re_ref, im_ref, twr_ref, twi_ref, out_re_ref, out_im_ref, *,
              n: int, radices: tuple[int, ...], inverse: bool):
    out_re, out_im = _mixed_radix_stages(
        re_ref[...], im_ref[...], n, twr_ref[...], twi_ref[...],
        radices=radices, inverse=inverse)
    out_re_ref[...] = out_re
    out_im_ref[...] = out_im


# ---------------------------------------------------------------------------
# Fused epilogues: transposed write (+ optional four-step twiddle)
# ---------------------------------------------------------------------------

def _fft_t_body(re_ref, im_ref, twr_ref, twi_ref, out_re_ref, out_im_ref, *,
                n: int, radices: tuple[int, ...], inverse: bool):
    """FFT a (1, tile_r, n) tile of rows, write it transposed (1, n, tile_r).

    The row->column hand-off of a 2-D (or four-step) transform costs zero
    extra HBM passes: the transpose happens in VMEM on the way out.
    """
    re = re_ref[0]
    im = im_ref[0]
    out_re, out_im = _mixed_radix_stages(re, im, n, twr_ref[...],
                                         twi_ref[...], radices=radices,
                                         inverse=inverse)
    out_re_ref[...] = out_re.T[None]
    out_im_ref[...] = out_im.T[None]


def _fft_t_twiddle_body(re_ref, im_ref, twr_ref, twi_ref, ftwr_ref, ftwi_ref,
                        out_re_ref, out_im_ref, *, n: int,
                        radices: tuple[int, ...], inverse: bool):
    """:func:`_fft_t_body` plus the four-step inter-pass twiddle epilogue.

    ``ftw*`` streams the (tile_r, n) window of the (R, n) twiddle matrix
    for this grid step, so the multiply that used to be a separate XLA op
    (an extra HBM read+write of the whole batch) rides the same pass.
    """
    re = re_ref[0]
    im = im_ref[0]
    out_re, out_im = _mixed_radix_stages(re, im, n, twr_ref[...],
                                         twi_ref[...], radices=radices,
                                         inverse=inverse)
    out_re, out_im = _cmul(out_re, out_im, ftwr_ref[...], ftwi_ref[...])
    out_re_ref[...] = out_re.T[None]
    out_im_ref[...] = out_im.T[None]


def _fft_axis1_body(re_ref, im_ref, twr_ref, twi_ref, out_re_ref,
                    out_im_ref, *, n: int, radices: tuple[int, ...],
                    inverse: bool):
    """FFT over axis -2 of a (1, R, tile_c) tile, layout preserved.

    Transpose-read + FFT + transpose-write, all inside VMEM: the column
    transform of a four-step / 2-D plan without any HBM transpose.
    """
    re = re_ref[0].T                                   # (tile_c, R)
    im = im_ref[0].T
    out_re, out_im = _mixed_radix_stages(re, im, n, twr_ref[...],
                                         twi_ref[...], radices=radices,
                                         inverse=inverse)
    out_re_ref[...] = out_re.T[None]                   # back to (R, tile_c)
    out_im_ref[...] = out_im.T[None]


def _fft_axis1_twiddle_body(re_ref, im_ref, twr_ref, twi_ref, ftwr_ref,
                            ftwi_ref, out_re_ref, out_im_ref, *, n: int,
                            radices: tuple[int, ...], inverse: bool):
    """:func:`_fft_axis1_body` + the four-step twiddle epilogue.

    ``ftw*`` streams the (tile_c, R) window of the (C, R) twiddle table:
    element [j, k] multiplies output bin k of column j.
    """
    re = re_ref[0].T
    im = im_ref[0].T
    out_re, out_im = _mixed_radix_stages(re, im, n, twr_ref[...],
                                         twi_ref[...], radices=radices,
                                         inverse=inverse)
    out_re, out_im = _cmul(out_re, out_im, ftwr_ref[...], ftwi_ref[...])
    out_re_ref[...] = out_re.T[None]
    out_im_ref[...] = out_im.T[None]


def _c2c_mul_body(re_ref, im_ref, twr_ref, twi_ref, fbr_ref, fbi_ref,
                  out_re_ref, out_im_ref, *, n: int,
                  radices: tuple[int, ...], inverse: bool):
    """FFT a (tile_b, n) tile, then multiply by a (T, n) filter bank.

    The bank multiply is a fused epilogue: the transformed tile is still
    resident in VMEM when it is broadcast against every filter row, so
    the (tile_b, T, n) product plane costs one HBM read of the tile plus
    one write of the plane — the standalone multiply pass of the unfused
    matched-filter formulation disappears.
    """
    xr, xi = _mixed_radix_stages(
        re_ref[...], im_ref[...], n, twr_ref[...], twi_ref[...],
        radices=radices, inverse=inverse)
    out_re, out_im = _cmul(xr[:, None, :], xi[:, None, :],
                           fbr_ref[...][None], fbi_ref[...][None])
    out_re_ref[...] = out_re
    out_im_ref[...] = out_im


@functools.partial(jax.jit,
                   static_argnames=("tile_b", "inverse", "interpret",
                                    "radices"))
def fft_mul_pallas(re: jax.Array, im: jax.Array, fbr: jax.Array,
                   fbi: jax.Array, *, tile_b: int = 8,
                   inverse: bool = False, interpret: bool = False,
                   radices: tuple[int, ...] = DEFAULT_RADICES):
    """Batched pow2 C2C FFT fused with a (T, N) filter-bank multiply.

    (B, N) re/im in, (B, T, N) re/im out: out[b, t] = FFT(x[b]) * f[t].
    The whole bank stays pinned in VMEM across grid steps, exactly like
    the stage-twiddle table.
    """
    b, n = re.shape
    t = fbr.shape[0]
    _require_pow2(n, "FFT length")
    _require_tiled(b, tile_b, "batch")
    if fbr.shape != (t, n):
        raise ValueError(
            f"filter-bank planes must be (T, {n}), got {fbr.shape}")
    grid = (b // tile_b,)
    in_spec = pl.BlockSpec((tile_b, n), lambda i: (i, 0))
    fb_spec = pl.BlockSpec((t, n), lambda i: (0, 0))
    out_spec = pl.BlockSpec((tile_b, t, n), lambda i: (i, 0, 0))
    twr, twi, tw_spec = _tables(n, radices)
    out_shape = [jax.ShapeDtypeStruct((b, t, n), re.dtype)] * 2
    fn = pl.pallas_call(
        functools.partial(_c2c_mul_body, n=n, radices=radices,
                          inverse=inverse),
        grid=grid,
        in_specs=[in_spec, in_spec, tw_spec, tw_spec, fb_spec, fb_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(re, im, twr, twi, fbr, fbi)


def _r2c_tile(x, twr, twi, swr, swi, *, n: int, radices: tuple[int, ...]):
    """Packed R2C of a (b, n) real tile -> (b, n/2+1) re/im planes."""
    b = x.shape[0]
    m = n // 2
    v = x.reshape(b, m, 2)
    zr, zi = _mixed_radix_stages(v[..., 0], v[..., 1], m, twr, twi,
                                 radices=radices, inverse=False)
    fr = jnp.concatenate([zr, zr[:, :1]], axis=1)      # wrap Z[m] = Z[0]
    fi = jnp.concatenate([zi, zi[:, :1]], axis=1)
    rr, ri = fr[:, ::-1], -fi[:, ::-1]                 # conj(Z[m-k])
    dr, di = fr - rr, fi - ri
    qr, qi = 0.5 * di, -0.5 * dr                       # Zo = -i/2 * d
    wr = swr.reshape(1, m + 1)
    wi = swi.reshape(1, m + 1)
    pr, pi = _cmul(qr, qi, wr, wi)
    return 0.5 * (fr + rr) + pr, 0.5 * (fi + ri) + pi  # X = Ze + W * Zo


def _r2c_t_body(x_ref, twr_ref, twi_ref, swr_ref, swi_ref,
                out_re_ref, out_im_ref, *, n: int, radices: tuple[int, ...]):
    """Fused R2C + transposed write: (1, tile_r, n) real -> (1, n/2+1, tile_r)."""
    out_re, out_im = _r2c_tile(x_ref[0], twr_ref[...], twi_ref[...],
                               swr_ref[...], swi_ref[...], n=n,
                               radices=radices)
    out_re_ref[...] = out_re.T[None]
    out_im_ref[...] = out_im.T[None]


def _transpose_body(*refs):
    """Tiled transpose: k (1, tr, tc) input planes -> k (1, tc, tr) planes."""
    k = len(refs) // 2
    for i in range(k):
        refs[k + i][...] = refs[i][0].T[None]


def _r2c_body(x_ref, twr_ref, twi_ref, swr_ref, swi_ref,
              out_re_ref, out_im_ref, *, n: int, radices: tuple[int, ...]):
    """Packed R2C: N reals -> N/2 complex FFT -> Hermitian split, fused."""
    out_re, out_im = _r2c_tile(x_ref[...], twr_ref[...], twi_ref[...],
                               swr_ref[...], swi_ref[...], n=n,
                               radices=radices)
    out_re_ref[...] = out_re
    out_im_ref[...] = out_im


def _c2r_body(xr_ref, xi_ref, twr_ref, twi_ref, swr_ref, swi_ref,
              out_ref, *, n: int, radices: tuple[int, ...]):
    """Packed C2R: Hermitian merge -> N/2 inverse FFT -> interleave."""
    ar, ai = xr_ref[...], xi_ref[...]                  # (tb, m+1)
    b = ar.shape[0]
    m = n // 2
    rr, ri = ar[:, ::-1], -ai[:, ::-1]                 # conj(X[m-k])
    er, ei = 0.5 * (ar + rr), 0.5 * (ai + ri)          # Ze (k = 0..m)
    dr, di = ar - rr, ai - ri
    wr = swr_ref[...].reshape(1, m + 1)
    wi = -swi_ref[...].reshape(1, m + 1)               # conj(W)
    qr, qi = _cmul(0.5 * dr, 0.5 * di, wr, wi)         # Zo
    zr = (er - qi)[:, :m]                              # Z = Ze + i * Zo
    zi = (ei + qr)[:, :m]
    zr, zi = _mixed_radix_stages(zr, zi, m, twr_ref[...], twi_ref[...],
                                 radices=radices, inverse=True)
    out_ref[...] = jnp.stack([zr, zi], axis=-1).reshape(b, n)


def _tables(n: int, radices: tuple[int, ...]):
    """Packed stage-twiddle constants + their broadcast BlockSpec."""
    twr, twi = packed_stage_twiddles(n, radices)
    spec = pl.BlockSpec(twr.shape, lambda i: (0, 0))
    return jnp.asarray(twr), jnp.asarray(twi), spec


def _split_tables(n: int):
    w = rfft_split_twiddles(n)
    swr = jnp.asarray(w.real, jnp.float32).reshape(1, -1)
    swi = jnp.asarray(w.imag, jnp.float32).reshape(1, -1)
    spec = pl.BlockSpec((1, n // 2 + 1), lambda i: (0, 0))
    return swr, swi, spec


@functools.partial(jax.jit,
                   static_argnames=("tile_b", "inverse", "interpret",
                                    "radices"))
def fft_pallas(re: jax.Array, im: jax.Array, *, tile_b: int = 8,
               inverse: bool = False, interpret: bool = False,
               radices: tuple[int, ...] = DEFAULT_RADICES):
    """Batched pow2 C2C FFT over the last axis; (B, N) re/im in, same out."""
    b, n = re.shape
    _require_pow2(n, "FFT length")
    _require_tiled(b, tile_b, "batch")
    if n == 1:
        return re, im
    grid = (b // tile_b,)
    spec = pl.BlockSpec((tile_b, n), lambda i: (i, 0))
    twr, twi, tw_spec = _tables(n, radices)
    out_shape = [jax.ShapeDtypeStruct((b, n), re.dtype)] * 2
    fn = pl.pallas_call(
        functools.partial(_c2c_body, n=n, radices=radices, inverse=inverse),
        grid=grid,
        in_specs=[spec, spec, tw_spec, tw_spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(re, im, twr, twi)


@functools.partial(jax.jit,
                   static_argnames=("tile_b", "interpret", "radices"))
def rfft_pallas(x: jax.Array, *, tile_b: int = 8, interpret: bool = False,
                radices: tuple[int, ...] = DEFAULT_RADICES):
    """Batched pow2 R2C FFT: (B, N) f32 in, (B, N/2+1) re/im out."""
    b, n = x.shape
    _require_pow2(n, "packed R2C/C2R length", minimum=4)
    _require_tiled(b, tile_b, "batch")
    m = n // 2
    grid = (b // tile_b,)
    in_spec = pl.BlockSpec((tile_b, n), lambda i: (i, 0))
    out_spec = pl.BlockSpec((tile_b, m + 1), lambda i: (i, 0))
    twr, twi, tw_spec = _tables(m, radices)
    swr, swi, sw_spec = _split_tables(n)
    out_shape = [jax.ShapeDtypeStruct((b, m + 1), x.dtype)] * 2
    fn = pl.pallas_call(
        functools.partial(_r2c_body, n=n, radices=radices),
        grid=grid,
        in_specs=[in_spec, tw_spec, tw_spec, sw_spec, sw_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(x, twr, twi, swr, swi)


@functools.partial(jax.jit,
                   static_argnames=("tile_r", "inverse", "interpret",
                                    "radices"))
def fft_t_pallas(re: jax.Array, im: jax.Array, *, tile_r: int = 8,
                 inverse: bool = False, interpret: bool = False,
                 radices: tuple[int, ...] = DEFAULT_RADICES):
    """Fused FFT + transposed write: (B, R, C) re/im in -> (B, C, R) out.

    One grid step FFTs a (tile_r, C) row tile and writes it into the
    (C, tile_r) column window of the output — the hand-off transpose of a
    2-D / four-step transform costs zero extra HBM passes.
    """
    b, r, c = re.shape
    _require_pow2(c, "row length C")
    _require_tiled(r, tile_r, "rows R")
    grid = (b, r // tile_r)
    in_spec = pl.BlockSpec((1, tile_r, c), lambda i, j: (i, j, 0))
    out_spec = pl.BlockSpec((1, c, tile_r), lambda i, j: (i, 0, j))
    twr, twi = packed_stage_twiddles(c, radices)
    tw_spec = pl.BlockSpec(twr.shape, lambda i, j: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((b, c, r), re.dtype)] * 2
    fn = pl.pallas_call(
        functools.partial(_fft_t_body, n=c, radices=radices,
                          inverse=inverse),
        grid=grid,
        in_specs=[in_spec, in_spec, tw_spec, tw_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(re, im, jnp.asarray(twr), jnp.asarray(twi))


@functools.partial(jax.jit,
                   static_argnames=("tile_r", "inverse", "interpret",
                                    "radices"))
def fft_t_twiddle_pallas(re: jax.Array, im: jax.Array, ftwr: jax.Array,
                         ftwi: jax.Array, *, tile_r: int = 8,
                         inverse: bool = False, interpret: bool = False,
                         radices: tuple[int, ...] = DEFAULT_RADICES):
    """:func:`fft_t_pallas` with the four-step inter-pass twiddle fused in.

    ``ftwr``/``ftwi`` is the (R, C) twiddle matrix; each grid step streams
    its (tile_r, C) window and multiplies before the transposed write.
    """
    b, r, c = re.shape
    _require_pow2(c, "row length C")
    _require_tiled(r, tile_r, "rows R")
    if ftwr.shape != (r, c):
        raise ValueError(
            f"twiddle planes must be ({r}, {c}), got {ftwr.shape}")
    grid = (b, r // tile_r)
    in_spec = pl.BlockSpec((1, tile_r, c), lambda i, j: (i, j, 0))
    ftw_spec = pl.BlockSpec((tile_r, c), lambda i, j: (j, 0))
    out_spec = pl.BlockSpec((1, c, tile_r), lambda i, j: (i, 0, j))
    twr, twi = packed_stage_twiddles(c, radices)
    tw_spec = pl.BlockSpec(twr.shape, lambda i, j: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((b, c, r), re.dtype)] * 2
    fn = pl.pallas_call(
        functools.partial(_fft_t_twiddle_body, n=c, radices=radices,
                          inverse=inverse),
        grid=grid,
        in_specs=[in_spec, in_spec, tw_spec, tw_spec, ftw_spec, ftw_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(re, im, jnp.asarray(twr), jnp.asarray(twi), ftwr, ftwi)


@functools.partial(jax.jit,
                   static_argnames=("tile_c", "inverse", "interpret",
                                    "radices"))
def fft_axis1_pallas(re: jax.Array, im: jax.Array, *, tile_c: int = 8,
                     inverse: bool = False, interpret: bool = False,
                     radices: tuple[int, ...] = DEFAULT_RADICES):
    """FFT over axis -2: (B, R, C) re/im in, (B, R, C) out, layout kept.

    Each grid step pins an (R, tile_c) column tile, transposes it in VMEM,
    runs the full stage pipeline over R and writes it back untransposed —
    the column pass of a 2-D / four-step transform in one HBM round trip.
    """
    b, r, c = re.shape
    _require_pow2(r, "column length R")
    _require_tiled(c, tile_c, "columns C")
    grid = (b, c // tile_c)
    spec = pl.BlockSpec((1, r, tile_c), lambda i, j: (i, 0, j))
    twr, twi = packed_stage_twiddles(r, radices)
    tw_spec = pl.BlockSpec(twr.shape, lambda i, j: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((b, r, c), re.dtype)] * 2
    fn = pl.pallas_call(
        functools.partial(_fft_axis1_body, n=r, radices=radices,
                          inverse=inverse),
        grid=grid,
        in_specs=[spec, spec, tw_spec, tw_spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(re, im, jnp.asarray(twr), jnp.asarray(twi))


@functools.partial(jax.jit,
                   static_argnames=("tile_c", "inverse", "interpret",
                                    "radices"))
def fft_axis1_twiddle_pallas(re: jax.Array, im: jax.Array, ftwr: jax.Array,
                             ftwi: jax.Array, *, tile_c: int = 8,
                             inverse: bool = False, interpret: bool = False,
                             radices: tuple[int, ...] = DEFAULT_RADICES):
    """:func:`fft_axis1_pallas` with a fused (C, R) twiddle epilogue:
    output element [.., k, j] is multiplied by ``ftw[j, k]`` in-kernel."""
    b, r, c = re.shape
    _require_pow2(r, "column length R")
    _require_tiled(c, tile_c, "columns C")
    if ftwr.shape != (c, r):
        raise ValueError(
            f"twiddle planes must be ({c}, {r}), got {ftwr.shape}")
    grid = (b, c // tile_c)
    spec = pl.BlockSpec((1, r, tile_c), lambda i, j: (i, 0, j))
    ftw_spec = pl.BlockSpec((tile_c, r), lambda i, j: (j, 0))
    twr, twi = packed_stage_twiddles(r, radices)
    tw_spec = pl.BlockSpec(twr.shape, lambda i, j: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((b, r, c), re.dtype)] * 2
    fn = pl.pallas_call(
        functools.partial(_fft_axis1_twiddle_body, n=r, radices=radices,
                          inverse=inverse),
        grid=grid,
        in_specs=[spec, spec, tw_spec, tw_spec, ftw_spec, ftw_spec],
        out_specs=[spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(re, im, jnp.asarray(twr), jnp.asarray(twi), ftwr, ftwi)


@functools.partial(jax.jit,
                   static_argnames=("tile_r", "interpret", "radices"))
def rfft_t_pallas(x: jax.Array, *, tile_r: int = 8, interpret: bool = False,
                  radices: tuple[int, ...] = DEFAULT_RADICES):
    """Fused R2C + transposed write: (B, R, C) f32 -> (B, C/2+1, R) re/im."""
    b, r, c = x.shape
    _require_pow2(c, "R2C row length C", minimum=4)
    _require_tiled(r, tile_r, "rows R")
    m = c // 2
    grid = (b, r // tile_r)
    in_spec = pl.BlockSpec((1, tile_r, c), lambda i, j: (i, j, 0))
    out_spec = pl.BlockSpec((1, m + 1, tile_r), lambda i, j: (i, 0, j))
    twr, twi = packed_stage_twiddles(m, radices)
    tw_spec = pl.BlockSpec(twr.shape, lambda i, j: (0, 0))
    swr, swi = rfft_split_twiddles(c).real, rfft_split_twiddles(c).imag
    swr = jnp.asarray(swr, jnp.float32).reshape(1, -1)
    swi = jnp.asarray(swi, jnp.float32).reshape(1, -1)
    sw_spec = pl.BlockSpec((1, m + 1), lambda i, j: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((b, m + 1, r), x.dtype)] * 2
    fn = pl.pallas_call(
        functools.partial(_r2c_t_body, n=c, radices=radices),
        grid=grid,
        in_specs=[in_spec, tw_spec, tw_spec, sw_spec, sw_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(x, jnp.asarray(twr), jnp.asarray(twi), swr, swi)


@functools.partial(jax.jit,
                   static_argnames=("tile_r", "tile_c", "interpret"))
def transpose_pallas(*planes: jax.Array, tile_r: int = 8, tile_c: int = 128,
                     interpret: bool = False):
    """Tiled last-two-axes transpose: k (B, R, C) planes -> k (B, C, R).

    Reads row-major (tile_r, tile_c) windows, writes them column-major —
    one HBM read + one write instead of an XLA transpose pair around a
    separate kernel.  Used for the plan graph's explicit transpose nodes
    (non-pow2 axes whose FFT pass cannot fuse the hand-off).
    """
    b, r, c = planes[0].shape
    _require_tiled(r, tile_r, "rows R")
    _require_tiled(c, tile_c, "columns C")
    grid = (b, r // tile_r, c // tile_c)
    in_spec = pl.BlockSpec((1, tile_r, tile_c), lambda i, j, k: (i, j, k))
    out_spec = pl.BlockSpec((1, tile_c, tile_r), lambda i, j, k: (i, k, j))
    out_shape = [jax.ShapeDtypeStruct((b, c, r), p.dtype) for p in planes]
    fn = pl.pallas_call(
        _transpose_body,
        grid=grid,
        in_specs=[in_spec] * len(planes),
        out_specs=[out_spec] * len(planes),
        out_shape=out_shape,
        interpret=interpret,
    )
    return tuple(fn(*planes))


@functools.partial(jax.jit,
                   static_argnames=("tile_b", "interpret", "radices"))
def irfft_pallas(re: jax.Array, im: jax.Array, *, tile_b: int = 8,
                 interpret: bool = False,
                 radices: tuple[int, ...] = DEFAULT_RADICES):
    """Batched pow2 C2R inverse: (B, N/2+1) re/im in, (B, N) f32 out."""
    b, m1 = re.shape
    m = m1 - 1
    n = 2 * m
    _require_pow2(n, "packed R2C/C2R length", minimum=4)
    _require_tiled(b, tile_b, "batch")
    grid = (b // tile_b,)
    in_spec = pl.BlockSpec((tile_b, m + 1), lambda i: (i, 0))
    out_spec = pl.BlockSpec((tile_b, n), lambda i: (i, 0))
    twr, twi, tw_spec = _tables(m, radices)
    swr, swi, sw_spec = _split_tables(n)
    fn = pl.pallas_call(
        functools.partial(_c2r_body, n=n, radices=radices),
        grid=grid,
        in_specs=[in_spec, in_spec, tw_spec, tw_spec, sw_spec, sw_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, n), re.dtype),
        interpret=interpret,
    )
    return fn(re, im, twr, twi, swr, swi)
