"""Pallas TPU kernels for the pipeline's compute hot-spots.

Each kernel package holds:
  <name>.py   pl.pallas_call body + BlockSpec VMEM tiling
  ops.py      jit'd public wrapper (dispatch, dtype plumbing, interpret mode)
  ref.py      pure-jnp oracle the tests assert against

Kernels:
  fft           fused-stage Stockham FFT, whole transform VMEM-resident
  harmonic_sum  strided decimate-and-add harmonic summing (no gathers);
                the fused *plane* variant feeds the pulsar pipeline
  dedisp        brute-force many-DM dedispersion (static shift-and-sum)
  spectrum      fused |X|^2 + mean/variance (one HBM pass)

The kernels target TPU (pl.pallas_call + BlockSpec); on this CPU container
they are validated in interpret mode (``repro.kernels.common.INTERPRET``).
"""
