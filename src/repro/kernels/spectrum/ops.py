"""Public wrapper for the fused spectrum kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import batch_tile, use_interpret
from repro.kernels.spectrum.spectrum_kernel import power_spectrum_stats_pallas
from repro.obs.ledger import record_launch


def power_spectrum_stats_kernel(x: jax.Array, *,
                                interpret: bool | None = None):
    """Complex spectra (..., N) -> (power (..., N), mean (...,), std (...,))."""
    if interpret is None:
        interpret = use_interpret()
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    lead, n = x.shape[:-1], x.shape[-1]
    if n == 0:
        raise ValueError("power_spectrum_stats_kernel needs a non-empty "
                         f"trailing axis, got shape {x.shape}")
    b = 1
    for d in lead:
        b *= d
    re = x.real.reshape(b, n).astype(jnp.float32)
    im = x.imag.reshape(b, n).astype(jnp.float32)
    tile = min(batch_tile(n, 4, buffers=5), b)
    pad = (-b) % tile
    if pad:
        re = jnp.pad(re, ((0, pad), (0, 0)))
        im = jnp.pad(im, ((0, pad), (0, 0)))
    p, mean, var = power_spectrum_stats_pallas(re, im, tile_b=tile,
                                               interpret=interpret)
    record_launch("power-spectrum-stats", grid=(re.shape[0] // tile,),
                  tile=(tile, n),
                  bytes_moved=4 * re.shape[0] * (3 * n + 2),
                  shape=(b, n))
    std = jnp.sqrt(jnp.maximum(var, 0.0))
    return (p[:b].reshape(*lead, n), mean[:b].reshape(lead),
            std[:b].reshape(lead))
