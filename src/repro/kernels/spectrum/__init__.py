from repro.kernels.spectrum.ops import power_spectrum_stats_kernel

__all__ = ["power_spectrum_stats_kernel"]
