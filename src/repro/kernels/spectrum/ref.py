"""Pure-jnp oracle for the fused power-spectrum + stats kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def power_spectrum_stats_ref(re: jax.Array, im: jax.Array):
    """(B, N) re/im spectrum -> (power (B,N), mean (B,), std (B,)).

    power = |X|^2 / N; mean/std taken over each spectrum row.
    """
    n = re.shape[-1]
    p = (re.astype(jnp.float32) ** 2 + im.astype(jnp.float32) ** 2) / n
    mean = jnp.mean(p, axis=-1)
    std = jnp.std(p, axis=-1)
    return p, mean, std
