"""Fused |X|^2 + mean/variance Pallas kernel.

The pipeline's power-spectrum and normalisation stages each re-read the
spectrum from HBM on the GPU implementation; fusing them halves the HBM
traffic of the non-FFT pipeline (a beyond-paper optimisation recorded in
EXPERIMENTS.md Sec. Perf).  One pass: read (re, im), emit power, and reduce
sum / sum-of-squares for the row statistics.

Grid: 1-D over batch tiles; (TILE_B, N) resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spectrum_body(re_ref, im_ref, p_ref, mean_ref, var_ref):
    re = re_ref[...].astype(jnp.float32)
    im = im_ref[...].astype(jnp.float32)
    n = re.shape[-1]
    p = (re * re + im * im) / n
    p_ref[...] = p
    mean = jnp.mean(p, axis=-1)
    mean_ref[...] = mean
    var_ref[...] = jnp.mean(p * p, axis=-1) - mean * mean


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def power_spectrum_stats_pallas(re: jax.Array, im: jax.Array, *,
                                tile_b: int = 8, interpret: bool = False):
    b, n = re.shape
    # A ValueError, not an assert: asserts vanish under ``python -O`` and
    # a non-dividing tile would silently corrupt the grid partition.
    if tile_b < 1 or b % tile_b:
        raise ValueError(
            f"batch={b} is not a multiple of its tile ({tile_b}); the ops "
            f"layer (repro.kernels.spectrum.ops) pads batches to tile "
            f"multiples — route through it or pass a dividing tile")
    row = pl.BlockSpec((tile_b, n), lambda i: (i, 0))
    vec = pl.BlockSpec((tile_b,), lambda i: (i,))
    fn = pl.pallas_call(
        _spectrum_body,
        grid=(b // tile_b,),
        in_specs=[row, row],
        out_specs=[row, vec, vec],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(re, im)
