"""Public wrapper for the dedispersion kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import batch_tile, use_interpret
from repro.kernels.dedisp.dedisp_kernel import dedisperse_pallas
from repro.obs.ledger import record_launch


def _as_static_delays(delays) -> tuple[tuple[int, ...], ...]:
    """Normalise a (D, C) delay table to the hashable tuple-of-tuples the
    jitted kernel takes as a static argument."""
    arr = np.asarray(delays)
    if arr.ndim != 2:
        raise ValueError(
            f"delays must be a (n_dm, nchan) table, got shape {arr.shape}")
    if not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"delays must be integer samples, got dtype {arr.dtype}; round "
            f"with FilterbankSpec.delay_samples / DispersionPlan")
    return tuple(tuple(int(d) for d in row) for row in arr)


def dedisperse_kernel(fb: jax.Array, delays, *,
                      interpret: bool | None = None) -> jax.Array:
    """(..., C, N) filterbanks -> (..., D, N) dedispersed time series.

    ``delays`` is a (D, C) integer-sample table (rows = DM trials); it is
    host-side and static — the kernel unrolls it at trace time, which is
    what makes the shift-and-sum gather-free on TPU.
    """
    if interpret is None:
        interpret = use_interpret()
    static = (_as_static_delays(delays)
              if not isinstance(delays, tuple) else delays)
    # A ValueError, not an assert: asserts vanish under ``python -O`` and
    # these guard caller input, not internal invariants.
    if getattr(fb, "ndim", 0) < 2:
        raise ValueError(
            f"dedisperse_kernel needs (..., nchan, ntime) input, got shape "
            f"{getattr(fb, 'shape', None)}")
    if jnp.issubdtype(jnp.asarray(fb).dtype, jnp.complexfloating):
        raise ValueError(
            f"filterbank data must be real, got dtype {fb.dtype}")
    fb = jnp.asarray(fb, jnp.float32)
    *lead, nchan, n = fb.shape
    if nchan == 0 or n == 0:
        raise ValueError(
            f"dedisperse_kernel needs non-empty channel/time axes, got "
            f"shape {fb.shape}")
    if static and len(static[0]) != nchan:
        raise ValueError(
            f"delay table covers {len(static[0])} channels; filterbank has "
            f"{nchan} (shape {fb.shape})")
    if not static:
        raise ValueError("delay table has no DM trials")
    b = 1
    for d in lead:
        b *= d
    x = fb.reshape(b, nchan, n)
    # VMEM holds the (tile, C, N) block plus the (tile, D, N) output.
    tile = min(batch_tile(n, 4, buffers=nchan + len(static)), b)
    pad = (-b) % tile
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
    out = dedisperse_pallas(x, static, tile_b=tile, interpret=interpret)[:b]
    padded = b + pad
    record_launch("dedisperse", grid=(padded // tile,),
                  tile=(tile, nchan, n),
                  bytes_moved=4 * padded * n * (nchan + len(static)),
                  shape=(b, nchan, n))
    return out.reshape(*lead, len(static), n)
