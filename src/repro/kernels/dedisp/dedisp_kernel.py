"""Dedispersion Pallas kernel — gather-free shift-and-sum.

GPU dedispersion engines walk a (DM, channel) delay table with global
gathers; TPU has no efficient gather, so we ADAPT the algorithm the same
way the harmonic-sum kernel does (DESIGN.md: rethink for the TPU memory
hierarchy): every delay is a *static* integer known at trace time, so

  x[c, t + d]  over t = 0..N-1-d  ==  the affine ``lax.slice`` x[c, d:]

zero-padded back to length N.  The kernel unrolls the (DM, delay) table
statically, grouping channels that share a delay so each distinct shift
is materialised once per DM trial; the (TILE_B, C, N) filterbank block
is loaded from HBM exactly once and every one of the D * C accumulations
reads it from VMEM.

Grid: 1-D over batch tiles (whole channels and the whole time axis stay
resident — a time-tiled variant would need halo reads of max-delay
samples per tile, the overhead-access t_o term the paper's Sec. 5
discussion prices).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _shift(x: jax.Array, d: int) -> jax.Array:
    """x[:, d:] zero-padded back to (B, N): the time-shift by ``d``."""
    if d == 0:
        return x
    b, n = x.shape
    return jnp.pad(jax.lax.slice(x, (0, d), (b, n)), ((0, 0), (0, d)))


def _dedisp_body(fb_ref, out_ref, *, delays: tuple[tuple[int, ...], ...]):
    fb = fb_ref[...]                                 # (B, C, N)
    for trial, row in enumerate(delays):
        # Channels sharing a delay are summed first, then shifted once.
        groups: dict[int, list[int]] = {}
        for ch, d in enumerate(row):
            groups.setdefault(d, []).append(ch)
        acc = None
        for d, chans in sorted(groups.items()):
            g = fb[:, chans[0], :]
            for ch in chans[1:]:
                g = g + fb[:, ch, :]
            g = _shift(g, d)
            acc = g if acc is None else acc + g
        out_ref[:, trial, :] = acc


@functools.partial(jax.jit,
                   static_argnames=("delays", "tile_b", "interpret"))
def dedisperse_pallas(fb: jax.Array,
                      delays: tuple[tuple[int, ...], ...], *,
                      tile_b: int = 1, interpret: bool = False):
    """(b, C, N) filterbanks + static (D, C) delay table -> (b, D, N)."""
    b, nchan, n = fb.shape
    # A ValueError, not an assert: asserts vanish under ``python -O`` and
    # a non-dividing tile would silently corrupt the grid partition.
    if tile_b < 1 or b % tile_b:
        raise ValueError(
            f"batch={b} is not a multiple of its tile ({tile_b}); the ops "
            f"layer (repro.kernels.dedisp.ops) pads batches to tile "
            f"multiples — route through it or pass a dividing tile")
    ndm = len(delays)
    for trial, row in enumerate(delays):
        if len(row) != nchan:
            raise ValueError(
                f"delay row {trial} has {len(row)} channels; filterbank "
                f"has {nchan} (shape {fb.shape})")
        for d in row:
            if not 0 <= d < n:
                raise ValueError(
                    f"delay {d} of trial {trial} outside [0, ntime={n}); "
                    f"clip the DM grid to the block length")
    fn = pl.pallas_call(
        functools.partial(_dedisp_body, delays=delays),
        grid=(b // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, nchan, n), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_b, ndm, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ndm, n), fb.dtype),
        interpret=interpret,
    )
    return fn(fb)
