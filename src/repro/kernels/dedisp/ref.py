"""Pure-jnp oracle for the dedispersion kernel.

Definition (zero-padded convention — see kernel docstring):

  out[..., d, t] = sum_c  x[..., c, t + delay[d, c]]   with x[..., c, i] = 0
                                                       for i >= ntime

implemented with the gather the TPU kernel avoids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dedisperse_ref(fb: jax.Array, delays) -> jax.Array:
    """(..., C, N) filterbanks + (D, C) delays -> (..., D, N)."""
    delays = jnp.asarray(np.asarray(delays, dtype=np.int64))
    n = fb.shape[-1]
    t = jnp.arange(n)
    idx = delays[:, :, None] + t[None, None, :]          # (D, C, N)
    valid = idx < n
    x = fb[..., None, :, :]                              # (..., 1, C, N)
    shape = (*fb.shape[:-2], *idx.shape)                 # (..., D, C, N)
    g = jnp.take_along_axis(jnp.broadcast_to(x, shape),
                            jnp.broadcast_to(idx.clip(0, n - 1), shape),
                            axis=-1)
    g = jnp.where(valid, g, 0.0)
    return jnp.sum(g, axis=-2)
