"""Brute-force incoherent dedispersion (many-DM shift-and-sum).

  dedisp_kernel  pl.pallas_call body: statically unrolled per-(DM, delay
                 group) ``lax.slice`` shifts over a VMEM-resident block
  ops            public wrapper (guards, batch tiling, lead-dim plumbing)
  ref            gather-based pure-jnp oracle the tests assert against
"""
from repro.kernels.dedisp.ops import dedisperse_kernel
from repro.kernels.dedisp.ref import dedisperse_ref

__all__ = ["dedisperse_kernel", "dedisperse_ref"]
