"""Serve a stream of FFT requests with energy-aware batching + DVFS.

Walks the full request lifecycle from docs/serving.md:
enqueue -> batch -> plan-cache -> clock-plan -> execute -> account.

Run:  PYTHONPATH=src python examples/serve_fft.py
"""
import numpy as np

from repro.core.hardware import TPU_V5E
from repro.serving import FFTService


def main():
    rng = np.random.default_rng(0)
    svc = FFTService(TPU_V5E, time_budget=0.10)

    # --- enqueue: three clients, two distinct shapes, one tight budget ---
    def payload(batch, n):
        return (rng.standard_normal((batch, n))
                + 1j * rng.standard_normal((batch, n))).astype(np.complex64)

    a = svc.submit(payload(4, 4096))
    b = svc.submit(payload(2, 4096))                       # coalesces with a
    c = svc.submit(payload(3, 1024), latency_budget=0.02)  # tight real-time

    # --- batch -> plan-cache -> clock-plan -> execute -> account ---------
    svc.drain()

    print("=== per-request receipts ===")
    for req in (a, b, c):
        r = svc.receipt(req)
        print(f"  request {req.request_id}: batch#{r.batch_id} "
              f"clock={r.clock_mhz:6.1f} MHz  "
              f"E={r.energy_j*1e6:7.2f} uJ ({r.joules_per_transform*1e6:.2f}"
              f" uJ/fft)  I_ef={r.i_ef_boost:.2f}  "
              f"latency={r.latency*1e3:.1f} ms")

    # A second wave of the same shapes: served entirely from the cache.
    for _ in range(4):
        svc.submit(payload(2, 4096))
    svc.drain()

    rep = svc.report()
    print("\n=== service report ===")
    print(f"  requests={rep.n_requests}  transforms={rep.n_transforms}  "
          f"batches={rep.n_batches}")
    print(f"  plan builds={rep.cache.plan_builds}  sweeps={rep.cache.sweeps}"
          f"  cache hits={rep.cache.hits} (hit rate "
          f"{100*rep.cache.hit_rate:.0f}%)")
    print(f"  joules/transform={rep.joules_per_transform*1e6:.2f} uJ  "
          f"service I_ef={rep.i_ef:.2f}")
    print(f"  p50={rep.p50_latency_s*1e3:.1f} ms  "
          f"p99={rep.p99_latency_s*1e3:.1f} ms  "
          f"clock locks={rep.clock_locks}")
    ts, fs = svc.clock.trace()
    print(f"  clock trace: {len(ts)} events, "
          f"f in [{fs.min():.0f}, {fs.max():.0f}] MHz")


if __name__ == "__main__":
    main()
