"""End-to-end driver: train a (reduced) assigned architecture for a few
hundred steps with checkpointing + fault tolerance, then print the DVFS
clock plan for the compiled step.

This is the deliverable (b) end-to-end example: it exercises the data
pipeline, model, optimizer, checkpoint manager and the paper's technique
in one run.

Run:  PYTHONPATH=src python examples/train_lm.py [--arch qwen2-0.5b]
"""
import argparse
import sys

from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    train_launch.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--lr", "3e-3",
        "--ckpt-dir", "/tmp/repro_example_ckpt",
        "--dvfs-report",
    ])


if __name__ == "__main__":
    main()
