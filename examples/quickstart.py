"""Quickstart: the paper's result in 60 seconds.

1. Sweep the V100 clock grid for a batched FFT (the paper's experiment).
2. Find the optimal and mean-optimal clocks (Table 3).
3. Apply the same machinery to a TPU-v5e LLM decode step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (TESLA_V100, TPU_V5E, FFTCase, fft_workload,
                        mean_optimal, roofline_workload, sweep)


def main():
    # --- 1. the paper's measurement, analytically -----------------------
    print("=== FFT DVFS sweep on the V100 (paper Secs. 4-5) ===")
    sweeps = []
    for logn in range(10, 21, 2):
        case = FFTCase(n=2**logn)
        res = sweep(fft_workload(case, TESLA_V100), TESLA_V100)
        sweeps.append(res)
        print(f"  N=2^{logn:<3} optimal={res.optimal.f:7.1f} MHz "
              f"({100*res.optimal_frequency_frac:5.1f}% of boost)  "
              f"power cut {100*res.power_reduction:4.1f}%  "
              f"slowdown {100*res.slowdown:5.2f}%  "
              f"I_ef {res.i_ef_boost:.2f}")

    # --- 2. Table 3: one clock for all lengths ---------------------------
    mo = mean_optimal(sweeps, TESLA_V100)
    print(f"\n  mean optimal clock = {mo.f_mean:.0f} MHz "
          f"(paper: 945 MHz); using it loses {mo.loss_pp:.1f} pp of I_ef")

    # --- 3. the same technique on a TPU LLM decode step ------------------
    print("\n=== The technique applied to an LLM decode step (TPU v5e) ===")
    # a memory-bound decode: weights + KV cache reads dominate
    prof = roofline_workload(
        "llm-decode", TPU_V5E,
        hlo_flops=2 * 4e9 * 128,          # 4B params, 128 sequences
        hbm_bytes=4e9 * 2 + 40e9,         # weights bf16 + 40 GB cache read
        issue_efficiency=0.75)
    res = sweep(prof, TPU_V5E, time_budget=0.10)
    print(f"  bound: memory   optimal={res.optimal.f:.0f} MHz "
          f"({100*res.optimal.f/TPU_V5E.f_max:.0f}% of boost)")
    print(f"  predicted power cut {100*res.power_reduction:.0f}% "
          f"at {100*res.slowdown:.1f}% slowdown  (I_ef {res.i_ef_boost:.2f})")


if __name__ == "__main__":
    main()
