"""The paper's Sec. 5.3 demonstration, end to end — plus the FDAS stage.

Runs the pulsar-search pipeline (R2C FFT -> power spectrum -> stats ->
harmonic sum -> S/N) on synthetic data with an injected pulsar through
``repro.fft.pipeline.pulsar_pipeline(real_input=True)`` — telescope
voltages are real, so the FFT stage does half the work and every routed
pass lands on the fused Pallas kernels (interpret mode on CPU).  Then the
Fourier-Domain Acceleration Search (``repro.search``) recovers an
injected *accelerated* pulsar from the same voltages, and the per-stage
DVFS clock plan reports the composite energy saving (Table 4).

Run:  PYTHONPATH=src python examples/pulsar_pipeline.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dvfs import sweep
from repro.core.hardware import TESLA_V100
from repro.core.scheduler import DVFSScheduler
from repro.fft.pipeline import (PipelineShape, fft_time_share,
                                pulsar_pipeline, stage_profiles)
from repro.search import TemplateBank, fdas_search


def main():
    # --- run the pipeline on real voltages with an injected pulsar -------
    n, batch = 4096, 4
    t = jnp.arange(n, dtype=jnp.float32)
    f0 = 96 / n
    key = jax.random.PRNGKey(0)
    noise = jax.random.normal(key, (batch, n))
    pulse = (jnp.sin(2 * jnp.pi * f0 * t) > 0.97).astype(jnp.float32)
    x = noise + 3.0 * pulse[None, :]

    # R2C route: half the FFT work, n/2+1 bins downstream (Sec. 5.3).
    snr = pulsar_pipeline(x, n_harmonics=16, real_input=True)
    nbins = snr.shape[-1]
    best = np.asarray(snr[:, :, 1:nbins - 1].max(axis=(1, 2)))
    peak_bin = int(np.asarray(snr[0].max(axis=0)[1:nbins - 1]).argmax()) + 1
    print(f"pulsar injected at bin 96 -> strongest S/N at bin {peak_bin}; "
          f"per-series peak S/N: {np.round(best, 1)}")

    # --- FDAS: recover an injected *accelerated* pulsar ------------------
    s = np.arange(n) / n
    k0, z = 700, 4.0                       # start bin, drift in bins
    accel = (0.4 * np.cos(2 * np.pi * (k0 * s + 0.5 * z * s * s))
             ).astype(np.float32)
    xa = np.asarray(noise) + accel[None, :]
    bank = TemplateBank.linear(zmax=8, n_templates=9)
    res = fdas_search(jnp.asarray(xa), bank, threshold=8.0,
                      max_candidates=4)
    print(f"\nFDAS: injected drift z={z:+.0f} bins at bin {k0}; "
          f"bank drifts {bank.drifts}")
    c = res.candidates
    for b in range(batch):
        rows = [
            f"(z={bank.drifts[int(ti)]:+.0f}, bin={int(bi)}, "
            f"P={float(p):.0f})"
            for ti, bi, p in zip(np.asarray(c.template[b]),
                                 np.asarray(c.bin[b]),
                                 np.asarray(c.power[b])) if ti >= 0
        ]
        print(f"  series {b}: " + (", ".join(rows) if rows
                                   else "no candidates above threshold"))

    # --- the paper's energy play: lock the FFT stage's clock -------------
    dev = TESLA_V100
    shape = PipelineShape(batch=32, n=2**20, n_harmonics=16, real_input=True)
    profs = stage_profiles(shape, dev)
    share = fft_time_share(shape, dev)
    sched = DVFSScheduler(dev)
    fft_opt = sweep(profs[0], dev).optimal.f
    stages = sched.plan(profs, locked={profs[0].name: fft_opt})
    rep = sched.evaluate_pipeline(stages)
    print(f"\nDVFS plan (V100 model): FFT stage locked to {fft_opt:.0f} MHz")
    for st in rep.stages:
        print(f"  {st.name:<14} f={st.f:7.1f} MHz  t={st.time*1e3:7.2f} ms"
              f"  P={st.power:6.1f} W")
    print(f"FFT time share {100*share:.0f}%  ->  composite I_ef "
          f"{rep.i_ef:.3f} at {100*rep.slowdown:.2f}% slowdown "
          f"(paper Table 4: 1.24-1.29)")

    # the sampled power trace of Fig. 19
    ts, ps, fs = sched.power_trace(stages)
    print(f"power trace: {len(ts)} samples, "
          f"P range [{ps.min():.0f}, {ps.max():.0f}] W, "
          f"clock range [{fs.min():.0f}, {fs.max():.0f}] MHz")


if __name__ == "__main__":
    main()
