"""The paper's Sec. 5.3 demonstration, end to end.

Runs the pulsar-search pipeline (FFT -> power spectrum -> stats ->
harmonic sum -> S/N) on synthetic data with an injected pulsar, using the
Pallas kernels (interpret mode on CPU), then prints the per-stage DVFS
clock plan and the composite energy saving (Table 4).

Run:  PYTHONPATH=src python examples/pulsar_pipeline.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dvfs import sweep
from repro.core.hardware import TESLA_V100
from repro.core.scheduler import DVFSScheduler
from repro.fft.pipeline import PipelineShape, fft_time_share, stage_profiles
from repro.kernels.fft.ops import fft_kernel_c2c
from repro.kernels.harmonic_sum.ops import harmonic_sum_kernel
from repro.kernels.spectrum.ops import power_spectrum_stats_kernel


def main():
    # --- run the pipeline on data with an injected pulsar ----------------
    n, batch = 4096, 4
    t = jnp.arange(n, dtype=jnp.float32)
    f0 = 96 / n
    key = jax.random.PRNGKey(0)
    noise = jax.random.normal(key, (batch, n))
    pulse = (jnp.sin(2 * jnp.pi * f0 * t) > 0.97).astype(jnp.float32)
    x = noise + 3.0 * pulse[None, :]

    spec = fft_kernel_c2c(x.astype(jnp.complex64))
    power, mean, std = power_spectrum_stats_kernel(spec)
    hsums = harmonic_sum_kernel(power, 16)
    levels = hsums.shape[-2]
    h = (2.0 ** jnp.arange(levels))[:, None]
    snr = (hsums - h * mean[:, None, None]) / (jnp.sqrt(h)
                                               * std[:, None, None])
    best = np.asarray(snr[:, :, 1: n // 2].max(axis=(1, 2)))
    peak_bin = int(np.asarray(snr[0].max(axis=0)[1: n // 2]).argmax()) + 1
    print(f"pulsar injected at bin 96 -> strongest S/N at bin {peak_bin}; "
          f"per-series peak S/N: {np.round(best, 1)}")

    # --- the paper's energy play: lock the FFT stage's clock -------------
    dev = TESLA_V100
    shape = PipelineShape(batch=32, n=2**20, n_harmonics=16)
    profs = stage_profiles(shape, dev)
    share = fft_time_share(shape, dev)
    sched = DVFSScheduler(dev)
    fft_opt = sweep(profs[0], dev).optimal.f
    stages = sched.plan(profs, locked={profs[0].name: fft_opt})
    rep = sched.evaluate_pipeline(stages)
    print(f"\nDVFS plan (V100 model): FFT stage locked to {fft_opt:.0f} MHz")
    for st in rep.stages:
        print(f"  {st.name:<14} f={st.f:7.1f} MHz  t={st.time*1e3:7.2f} ms"
              f"  P={st.power:6.1f} W")
    print(f"FFT time share {100*share:.0f}%  ->  composite I_ef "
          f"{rep.i_ef:.3f} at {100*rep.slowdown:.2f}% slowdown "
          f"(paper Table 4: 1.24-1.29)")

    # the sampled power trace of Fig. 19
    ts, ps, fs = sched.power_trace(stages)
    print(f"power trace: {len(ts)} samples, "
          f"P range [{ps.min():.0f}, {ps.max():.0f}] W, "
          f"clock range [{fs.min():.0f}, {fs.max():.0f}] MHz")


if __name__ == "__main__":
    main()
