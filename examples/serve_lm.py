"""Serve a (reduced) assigned architecture with batched requests:
prefill + greedy decode, plus the per-phase DVFS clock plan showing the
paper's headline — decode is memory-bound, so the clock drops ~40% nearly
for free while prefill stays near boost.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen2-0.5b]
"""
import argparse

from repro.launch import serve as serve_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()
    serve_launch.main([
        "--arch", args.arch, "--reduced",
        "--batch", "4", "--prompt-len", "32", "--gen", "16",
        "--dvfs-report",
    ])


if __name__ == "__main__":
    main()
